//! Lane paging & prefix-cache property suite — the pinning tests for
//! the coordinator's [`LaneBank`] and [`PrefixCache`]
//! (`rust/src/coordinator/lane_bank.rs`).
//!
//! What this file pins:
//! * page-out → page-in round-trips preserve lane state for every
//!   feature map × storage dtype the build knows: bitwise for f32
//!   (poly and FAVOR+), within the same pinned f16/int8 readout bounds
//!   as `kernel_equivalence.rs` for quantized polynomial banks.
//! * a session resumed from a disk page decodes bitwise-identically to
//!   one that never left the resident bank (position included).
//! * corrupt, truncated, oversized, and cross-map page files are
//!   rejected as typed [`WireError`]s via [`BankError::Wire`]; the bank
//!   entry stays registered, the failure is repeatable, and no other
//!   lane is disturbed.
//! * prefill(prefix ∥ suffix) ≡ clone(cached prefix) + prefill(suffix)
//!   within 1e-5, including the sharded-prefill merge interaction.
//! * the scheduler composes both subsystems: prefix hits are counted
//!   and completed sessions spill under resident pressure.

use fast::attention::feature_map::{FeatureMap, WireError};
use fast::attention::{normalize, FeatureMapSpec, Mechanism, MultiHeadAttention,
                      StateDtype};
use fast::coordinator::request::{GenRequest, Ticket};
use fast::coordinator::{BankError, LaneBank, LaneBankConfig,
                        NativeSchedulerConfig, PrefixCache};
use fast::model::native::{random_bundle, BatchedDecodeState, NativeModel};
use fast::model::ModelConfig;
use fast::util::prop::assert_allclose;
use fast::util::rng::Rng;

mod common;

/// Same pinned quantized-readout bounds as `kernel_equivalence.rs`.
const F16_TOL: f32 = 2.5e-3;
const INT8_TOL: f32 = 4e-2;

/// Tiny serving shape: the suite pins the paging seam, not the model.
fn tiny() -> (ModelConfig, NativeModel) {
    let mcfg = ModelConfig {
        vocab: 16, n_ctx: 32, d_model: 8, n_layers: 2, n_heads: 2,
        attn: Mechanism::Fastmax2, causal: true, n_classes: 0,
    };
    let bundle = random_bundle(&mcfg, 33);
    let model = NativeModel::from_bundle(mcfg.clone(), &bundle).unwrap();
    (mcfg, model)
}

fn temp_bank(name: &str) -> (std::path::PathBuf, LaneBank) {
    let dir = std::env::temp_dir().join(format!("fast_lane_paging_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    let bank = LaneBank::new(&LaneBankConfig {
        max_resident: 0,
        page_dir: Some(dir.clone()),
    }).unwrap();
    (dir, bank)
}

/// Page-out → page-in round-trip parity, per feature map × dtype. The
/// page file must reproduce the exported wire frame bitwise (pages are
/// plain f32), and readmission through the typed `try_import_lane`
/// path must read out exactly (f32, FAVOR+) or within the pinned
/// quantization bounds (f16/int8 polynomial banks).
#[test]
fn page_roundtrip_readout_parity_per_map_and_dtype() {
    let d = 6usize;
    let cases: &[(&str, StateDtype, Option<f32>)] = &[
        ("poly:p1", StateDtype::F32, None),
        ("poly:p2", StateDtype::F32, None),
        ("poly:p1", StateDtype::F16, Some(F16_TOL)),
        ("poly:p2", StateDtype::F16, Some(F16_TOL)),
        ("poly:p1", StateDtype::Int8, Some(INT8_TOL)),
        ("poly:p2", StateDtype::Int8, Some(INT8_TOL)),
        ("favor:m16", StateDtype::F32, None),
    ];
    let (dir, mut bank) = temp_bank("roundtrip");
    let mut rng = Rng::new(41);
    for (i, &(spec, dtype, tol)) in cases.iter().enumerate() {
        let map = FeatureMapSpec::parse(spec).unwrap().build(d, 13);
        let mut eng = MultiHeadAttention::with_map(1, 2, map)
            .with_state_dtype(dtype);
        let lanes = eng.lanes();
        for _ in 0..6 {
            let kv = rng.normal_vec(2 * lanes * d);
            let (k, v) = kv.split_at(lanes * d);
            eng.absorb_batch(k, v);
        }
        let frame = eng.export_lane(0);
        let sid = i as u64;
        bank.park(sid, vec![frame.clone()], 6).unwrap();
        bank.flush().unwrap();
        assert!(bank.is_paged(sid), "{spec} {dtype:?} must spill");
        let (frames, pos) = bank.take(sid).unwrap();
        assert_eq!(pos, 6, "{spec} {dtype:?}");
        assert_eq!(frames.len(), 1, "{spec} {dtype:?}");
        assert_eq!(frames[0], frame,
                   "{spec} {dtype:?}: page file must round-trip bitwise");
        // readmit through the typed admission path; compare readout of
        // the original lane vs the readmitted one
        eng.try_import_lane(1, &frames[0]).unwrap();
        let q = normalize(&rng.normal_vec(d), 1, d);
        let (mut want, mut got) = (vec![0.0f32; d], vec![0.0f32; d]);
        eng.map().readout(eng.state(0), &q, &mut want);
        eng.map().readout(eng.state(1), &q, &mut got);
        match tol {
            None => assert_eq!(got, want, "{spec} {dtype:?} must be exact"),
            Some(t) => assert_allclose(&got, &want, t, t),
        }
        assert_eq!(eng.lane_cnt(1), 6.0, "{spec} {dtype:?} token count");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A session paged to disk, wiped from its lane, and resumed decodes
/// bitwise-identically to one that never left the resident bank.
#[test]
fn decode_resumes_bitwise_from_a_paged_session() {
    let (mcfg, model) = tiny();
    let (dir, mut bank) = temp_bank("resume");
    let mut cont = BatchedDecodeState::new_with_opts(
        &mcfg, 1, StateDtype::F32, None, 0).unwrap();
    let mut evicted = BatchedDecodeState::new_with_opts(
        &mcfg, 1, StateDtype::F32, None, 0).unwrap();
    let prompt = [1i32, 2, 3, 4, 5];
    let logits = model.prefill_seq(&prompt, &mut cont, 0, 0).unwrap();
    let logits_b = model.prefill_seq(&prompt, &mut evicted, 0, 0).unwrap();
    assert_eq!(logits, logits_b);
    // park, spill to disk, wipe the lane, resume from the page file
    bank.park_from(77, &evicted, 0).unwrap();
    bank.flush().unwrap();
    assert!(bank.is_paged(77));
    evicted.reset_seq(0);
    assert_eq!(evicted.pos[0], 0);
    let pos = bank.resume_into(77, &mut evicted, 0).unwrap();
    assert_eq!(pos, prompt.len(), "position must travel with the page");
    assert_eq!(evicted.pos[0], cont.pos[0]);
    assert_eq!(bank.page_in(), 1);
    assert!(!bank.contains(77), "successful resume consumes the entry");
    // identical greedy decode from here on, bitwise
    let mut t = fast::model::sampler::argmax(&logits) as i32;
    for step in 0..4 {
        let la = model.decode_step_batch(&[t], &mut cont).unwrap().to_vec();
        let lb = model.decode_step_batch(&[t], &mut evicted).unwrap().to_vec();
        assert_eq!(la, lb, "decode diverged at step {step} after page-in");
        t = fast::model::sampler::argmax(&la) as i32;
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// prefill(prefix ∥ suffix) ≡ clone(cached prefix) + prefill(suffix),
/// within 1e-5, for serial and sharded prefill (the cached state is a
/// merged shard tree when shards ≥ 2 — the interaction under test).
#[test]
fn prefix_clone_matches_full_prefill() {
    let (mcfg, model) = tiny();
    let prefix = [1i32, 2, 3, 4, 5, 6];
    let suffix = [7i32, 8, 9];
    let full: Vec<i32> = prefix.iter().chain(&suffix).copied().collect();
    for shards in [0usize, 3] {
        let mut a = BatchedDecodeState::new_with_opts(
            &mcfg, 1, StateDtype::F32, None, 0).unwrap();
        let la = model.prefill_seq(&full, &mut a, 0, shards).unwrap();
        let cache = PrefixCache::build(&model, StateDtype::F32, None, 0, 0,
                                       &prefix, shards).unwrap();
        assert_eq!(cache.len(), prefix.len());
        assert_eq!(cache.tokens(), &prefix);
        let mut b = BatchedDecodeState::new_with_opts(
            &mcfg, 1, StateDtype::F32, None, 0).unwrap();
        cache.clone_into(&mut b, 0).unwrap();
        assert_eq!(b.pos[0], prefix.len(),
                   "clone must position the lane after the prefix");
        let lb = model.prefill_seq(&suffix, &mut b, 0, shards).unwrap();
        assert_allclose(&lb, &la, 1e-5, 1e-5);
        assert_eq!(b.pos[0], a.pos[0], "shards={shards}");
        // the post-prefill lane states agree frame by frame too
        for (fa, fb) in a.export_seq(0).iter().zip(b.export_seq(0).iter()) {
            assert_allclose(fb, fa, 1e-5, 1e-5);
        }
    }
}

/// Corrupt, truncated, oversized, and cross-map page files fail as
/// typed errors; the bank entry stays registered (same failure twice),
/// file-level corruption never touches any lane, and frame-level
/// rejection resets only the target lane.
#[test]
fn corrupt_and_cross_map_pages_fail_typed_with_bank_intact() {
    let (mcfg, model) = tiny();
    let (dir, mut bank) = temp_bank("corrupt");
    let mut st = BatchedDecodeState::new_with_opts(
        &mcfg, 2, StateDtype::F32, None, 0).unwrap();
    model.prefill_seq(&[1, 2, 3, 4], &mut st, 0, 0).unwrap();
    model.prefill_seq(&[5, 6, 7], &mut st, 1, 0).unwrap();
    bank.park_from(7, &st, 0).unwrap();
    bank.flush().unwrap();
    let page = bank.page_path(7).unwrap();
    assert!(page.exists(), "flushed page must be on disk");
    let good = std::fs::read(&page).unwrap();
    let target_before = st.export_seq(0);
    let bystander = st.export_seq(1);

    // torn header: fewer bytes than the page header
    std::fs::write(&page, &good[..3]).unwrap();
    match bank.resume_into(7, &mut st, 0) {
        Err(BankError::Wire(WireError::Header { got: 3 })) => {}
        other => panic!("torn header must be typed, got {other:?}"),
    }
    // flipped magic byte
    let mut bad = good.clone();
    bad[0] ^= 0xff;
    std::fs::write(&page, &bad).unwrap();
    assert!(matches!(bank.resume_into(7, &mut st, 0),
                     Err(BankError::Wire(WireError::BadMagic))));
    // truncated payload
    std::fs::write(&page, &good[..good.len() - 4]).unwrap();
    assert!(matches!(bank.resume_into(7, &mut st, 0),
                     Err(BankError::Wire(WireError::Length { .. }))));
    // trailing garbage
    let mut long = good.clone();
    long.extend_from_slice(&[0u8; 4]);
    std::fs::write(&page, &long).unwrap();
    assert!(matches!(bank.resume_into(7, &mut st, 0),
                     Err(BankError::Wire(WireError::Length { .. }))));
    // every file-level failure kept the entry and touched no lane
    assert!(bank.is_paged(7), "failed page-in must keep the entry");
    assert_eq!(st.export_seq(0), target_before);
    assert_eq!(st.export_seq(1), bystander);
    assert_eq!(st.pos[0], 4);

    // restore the original bytes: the same entry resumes fine
    std::fs::write(&page, &good).unwrap();
    assert_eq!(bank.resume_into(7, &mut st, 0).unwrap(), 4);
    assert_eq!(st.pos[0], 4);

    // cross-map: a FAVOR+ session page readmitted into a poly bank is
    // a typed mismatch; the target lane is reset to a safe idle state,
    // the entry survives, and the failure repeats identically
    let mut fst = BatchedDecodeState::new_with_opts(
        &mcfg, 1, StateDtype::F32,
        Some(FeatureMapSpec::Favor { m: 16 }), 5).unwrap();
    model.prefill_seq(&[1, 2, 3], &mut fst, 0, 0).unwrap();
    bank.park_from(9, &fst, 0).unwrap();
    bank.flush().unwrap();
    for attempt in 0..2 {
        match bank.resume_into(9, &mut st, 0) {
            Err(BankError::Wire(WireError::MapMismatch { .. })) => {}
            other => panic!("attempt {attempt}: cross-map page must be a \
                             typed mismatch, got {other:?}"),
        }
        assert!(bank.is_paged(9), "rejected page must stay registered");
        assert_eq!(st.pos[0], 0, "attempt {attempt}");
        assert!(!st.active[0],
                "a lane that failed readmission must not decode");
        assert_eq!(st.export_seq(1), bystander, "attempt {attempt}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The two subsystems compose in the scheduler: every admission hits
/// the prefix cache (skipping its prefill) and completed sessions
/// spill once the resident cap is exceeded.
#[test]
fn scheduler_composes_prefix_cache_and_paging() {
    let dir = std::env::temp_dir().join("fast_lane_paging_sched");
    let _ = std::fs::remove_dir_all(&dir);
    let prefix = vec![1i32, 2, 3, 4];
    let mut sched = common::native_sched_cfg(&NativeSchedulerConfig {
        batch: 1,
        max_resident_lanes: 1,
        page_dir: Some(dir.to_string_lossy().into_owned()),
        prefix: Some(prefix.clone()),
        ..Default::default()
    });
    let mut rxs = Vec::new();
    for i in 0..3u64 {
        let (tx, rx) = std::sync::mpsc::channel();
        assert!(sched.submit(Ticket::new(
            GenRequest::new(i, vec![5, 6], 3, 0.0), tx)));
        rxs.push(rx);
    }
    sched.run_to_completion().unwrap();
    for (i, rx) in rxs.iter().enumerate() {
        assert_eq!(rx.recv().unwrap().tokens.len(), 3, "req {i}");
    }
    assert_eq!(sched.metrics.prefix_hits, 3);
    assert_eq!(sched.metrics.prefill_tokens_saved, 3 * prefix.len() as u64);
    let bank = sched.bank().expect("bank must be enabled");
    assert_eq!(bank.registered(), 3);
    assert_eq!(bank.resident(), 1);
    assert_eq!(bank.paged(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
