//! Fuzz-style property tests for the pull JSON tokenizer
//! (`fast::util::json_pull`) — the parser on the serving request path.
//!
//! Four properties pinned here:
//! 1. round-trip: documents written by the tree writer tokenize back to
//!    the identical `Json` value (compact AND pretty-printed);
//! 2. truncation: every strict prefix of a container-rooted document is
//!    a typed `Truncated` error, never a panic or a silent success;
//! 3. depth: nesting at the configured limit parses, one past it is a
//!    typed `DepthLimit` error;
//! 4. robustness: random byte mutations of valid documents never panic
//!    — every outcome is `Ok` or a typed error.
//!
//! The bottom section mirrors docs/WIRE_PROTOCOL.md: one test per
//! documented frame type, so the spec doubles as the tokenizer's test
//! plan (adding a frame to the spec means adding a case here).

use fast::util::json::Json;
use fast::util::json_pull::{to_value, ErrorKind, Token, Tokenizer};
use fast::util::prop::{check, Config};
use fast::util::rng::Rng;

/// Characters worth stressing: ASCII, every escape class the writer
/// emits (quote, backslash, newline, tab, control), and multi-byte
/// UTF-8 including an astral-plane char (surrogate-pair escape path).
const CHAR_POOL: &[char] = &[
    'a', 'Z', '0', ' ', ':', ',', '{', '[', '"', '\\', '\n', '\r', '\t',
    '\u{1}', '\u{7f}', 'é', 'ß', '中', '\u{2028}', '😀',
];

fn gen_string(rng: &mut Rng) -> String {
    let len = rng.below(8);
    (0..len).map(|_| *rng.choose(CHAR_POOL)).collect()
}

/// A Display-round-trip-safe number: integers (the writer prints them
/// without a fractional part) or dyadic fractions (exact in binary, so
/// shortest-repr Display round-trips through `parse::<f64>`).
fn gen_num(rng: &mut Rng) -> f64 {
    let base = rng.next_u32() as i64 - (u32::MAX / 2) as i64;
    if rng.bool(0.5) {
        base as f64
    } else {
        base as f64 / 256.0
    }
}

fn gen_value(rng: &mut Rng, depth: usize) -> Json {
    let leaf_only = depth >= 4;
    match if leaf_only { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => Json::Num(gen_num(rng)),
        3 => Json::Str(gen_string(rng)),
        4 => Json::arr((0..rng.below(4)).map(|_| gen_value(rng, depth + 1))),
        _ => {
            let mut obj = Json::obj(vec![]);
            for _ in 0..rng.below(4) {
                let key = gen_string(rng);
                obj.insert(&key, gen_value(rng, depth + 1));
            }
            obj
        }
    }
}

/// A container-rooted document (what the wire protocol actually sends).
fn gen_doc(rng: &mut Rng) -> Json {
    if rng.bool(0.5) {
        let mut obj = Json::obj(vec![]);
        for _ in 0..rng.below(5) {
            let key = gen_string(rng);
            obj.insert(&key, gen_value(rng, 1));
        }
        obj
    } else {
        Json::arr((0..rng.below(5)).map(|_| gen_value(rng, 1)))
    }
}

#[test]
fn generated_documents_roundtrip() {
    check(Config::cases(300), "writer→tokenizer round-trip", |rng| {
        let doc = gen_doc(rng);
        let s = doc.to_string();
        let back = to_value(s.as_bytes())
            .unwrap_or_else(|e| panic!("tokenize {s:?}: {e}"));
        assert_eq!(back, doc, "pull parse diverged on {s:?}");
        // and agree with the tree parser on the same bytes
        assert_eq!(back, Json::parse(&s).expect("tree parse"));
    });
}

#[test]
fn pretty_printed_documents_tokenize() {
    check(Config::cases(150), "pretty-printed round-trip", |rng| {
        let doc = gen_doc(rng);
        let s = doc.pretty();
        let back = to_value(s.as_bytes())
            .unwrap_or_else(|e| panic!("tokenize pretty {s:?}: {e}"));
        assert_eq!(back, doc);
    });
}

#[test]
fn every_strict_prefix_is_truncated() {
    check(Config::cases(120), "prefixes are Truncated", |rng| {
        let doc = gen_doc(rng);
        let s = doc.to_string();
        let bytes = s.as_bytes();
        for cut in 0..bytes.len() {
            match to_value(&bytes[..cut]) {
                Err(e) => assert_eq!(
                    e.kind, ErrorKind::Truncated,
                    "prefix {:?} of {s:?} gave {e}",
                    String::from_utf8_lossy(&bytes[..cut])),
                Ok(v) => panic!("prefix len {cut} of {s:?} parsed as {v}"),
            }
        }
    });
}

#[test]
fn depth_limit_boundary_is_exact() {
    check(Config::cases(40), "depth limit boundary", |rng| {
        let limit = rng.range(1, 33);
        let at = format!("{}1{}", "[".repeat(limit), "]".repeat(limit));
        let over = format!("{}1{}", "[".repeat(limit + 1), "]".repeat(limit + 1));
        let drive = |s: &str| {
            let mut tz = Tokenizer::with_max_depth(s.as_bytes(), limit);
            loop {
                match tz.next() {
                    Ok(Some(_)) => {}
                    Ok(None) => return Ok(()),
                    Err(e) => return Err(e),
                }
            }
        };
        drive(&at).unwrap_or_else(|e| panic!("depth {limit} at limit: {e}"));
        let err = drive(&over).expect_err("one past the limit must fail");
        assert_eq!(err.kind, ErrorKind::DepthLimit);
    });
}

#[test]
fn random_byte_mutations_never_panic() {
    check(Config::cases(400), "mutations are panic-free", |rng| {
        let doc = gen_doc(rng);
        let mut bytes = doc.to_string().into_bytes();
        if bytes.is_empty() {
            return;
        }
        for _ in 0..rng.range(1, 5) {
            let i = rng.below(bytes.len());
            bytes[i] = rng.next_u32() as u8;
        }
        // outcome may be Ok (mutation kept it valid) or a typed error;
        // the property is simply that next() never panics and always
        // terminates
        let mut tz = Tokenizer::new(&bytes);
        let mut steps = 0usize;
        loop {
            match tz.next() {
                Ok(Some(_)) => {}
                Ok(None) | Err(_) => break,
            }
            steps += 1;
            assert!(steps <= 2 * bytes.len() + 8,
                    "tokenizer failed to terminate on {bytes:?}");
        }
        let _ = to_value(&bytes);
    });
}

// ---------------------------------------------------------------------
// docs/WIRE_PROTOCOL.md frame-type coverage: one test per documented
// frame. Each example below appears verbatim in the spec.
// ---------------------------------------------------------------------

/// Tokenize a one-line frame and return (keys in order, value count).
fn walk(frame: &str) -> Vec<String> {
    let mut tz = Tokenizer::new(frame.as_bytes());
    let mut keys = Vec::new();
    loop {
        match tz.next().unwrap_or_else(|e| panic!("{frame:?}: {e}")) {
            Some(Token::Key(k)) => {
                let mut s = String::new();
                k.decode_into(&mut s).unwrap();
                keys.push(s);
            }
            Some(_) => {}
            None => break,
        }
    }
    keys
}

#[test]
fn frame_generate_request() {
    let f = r#"{"prompt": "DUKE:", "max_tokens": 32, "temperature": 0.8}"#;
    assert_eq!(walk(f), ["prompt", "max_tokens", "temperature"]);
    let v = to_value(f.as_bytes()).unwrap();
    assert_eq!(v.get("prompt").as_str(), Some("DUKE:"));
    assert_eq!(v.get("max_tokens").as_usize(), Some(32));
}

#[test]
fn frame_streaming_generate_request() {
    let f = r#"{"prompt": "DUKE:", "max_tokens": 8, "stream": true, "v": 1}"#;
    assert_eq!(walk(f), ["prompt", "max_tokens", "stream", "v"]);
    let v = to_value(f.as_bytes()).unwrap();
    assert_eq!(v.get("stream").as_bool(), Some(true));
    assert_eq!(v.get("v").as_usize(), Some(1));
}

#[test]
fn frame_generate_response() {
    let f = concat!(r#"{"id": 1, "text": "First Citizen", "tokens": 13, "#,
                    r#""ttft_ms": 2.1, "latency_ms": 9.8, "finish": "max_tokens"}"#);
    let v = to_value(f.as_bytes()).unwrap();
    assert_eq!(v.get("id").as_usize(), Some(1));
    assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
    assert_eq!(v.get("tokens").as_usize(), Some(13));
}

#[test]
fn frame_token_event() {
    let f = r#"{"id": 2, "event": "token", "index": 0, "token": "F"}"#;
    let v = to_value(f.as_bytes()).unwrap();
    assert_eq!(v.get("event").as_str(), Some("token"));
    assert_eq!(v.get("index").as_usize(), Some(0));
    assert_eq!(v.get("token").as_str(), Some("F"));
}

#[test]
fn frame_done_event() {
    let f = concat!(r#"{"id": 2, "event": "done", "text": "First", "tokens": 5, "#,
                    r#""ttft_ms": 2.1, "latency_ms": 7.7, "finish": "max_tokens"}"#);
    let v = to_value(f.as_bytes()).unwrap();
    assert_eq!(v.get("event").as_str(), Some("done"));
    assert_eq!(v.get("text").as_str(), Some("First"));
}

#[test]
fn frame_error() {
    let plain = r#"{"error": "frame too large", "code": "oversized_frame"}"#;
    let v = to_value(plain.as_bytes()).unwrap();
    assert_eq!(v.get("code").as_str(), Some("oversized_frame"));
    let with_id = r#"{"id": 7, "error": "queue full", "code": "queue_full"}"#;
    let v = to_value(with_id.as_bytes()).unwrap();
    assert_eq!(v.get("id").as_usize(), Some(7));
    assert_eq!(v.get("code").as_str(), Some("queue_full"));
}

#[test]
fn frame_stats_command_and_response() {
    let cmd = r#"{"cmd": "stats"}"#;
    assert_eq!(walk(cmd), ["cmd"]);
    let resp = concat!(r#"{"backend": "native", "requests_completed": 3, "#,
                       r#""queue_depth": 0, "state_bytes": 65536, "conn_open": 1}"#);
    let v = to_value(resp.as_bytes()).unwrap();
    assert_eq!(v.get("backend").as_str(), Some("native"));
    assert_eq!(v.get("queue_depth").as_usize(), Some(0));
}

#[test]
fn frame_shutdown_command_and_ack() {
    let cmd = r#"{"cmd": "shutdown"}"#;
    let v = to_value(cmd.as_bytes()).unwrap();
    assert_eq!(v.get("cmd").as_str(), Some("shutdown"));
    let ack = r#"{"ok":true}"#;
    let v = to_value(ack.as_bytes()).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(true));
}
