//! Batched-engine parity: the (B, H, N, D) `MultiHeadAttention` engine
//! must match the single-head path (unmasked + causal, p ∈ {1, 2}),
//! batched decode must match the causal sweep, and the whole-model
//! batched decode must match the per-sequence loop. Runs with no
//! artifacts — everything here is the native substrate.

use fast::attention::{fastmax_attention, FastmaxOpts, Mechanism, MultiHeadAttention};
use fast::coordinator::request::{GenRequest, Ticket};
use fast::coordinator::{NativeScheduler, NativeSchedulerConfig};
use fast::model::native::{random_bundle, BatchedDecodeState, DecodeState, NativeModel};
use fast::model::ModelConfig;
use fast::util::prop::assert_allclose;
use fast::util::rng::Rng;

fn gen(len: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (rng.normal_vec(len), rng.normal_vec(len), rng.normal_vec(len))
}

#[test]
fn batched_forward_matches_single_head_all_variants() {
    for p in [1usize, 2] {
        for causal in [false, true] {
            let (b, h, n, d) = (4usize, 3usize, 96usize, 16usize);
            let lanes = b * h;
            let (q, k, v) = gen(lanes * n * d, 1000 + p as u64 + causal as u64 * 10);
            let mha = MultiHeadAttention::new(b, h, d, p);
            let mut batched = vec![0.0f32; lanes * n * d];
            mha.forward(&q, &k, &v, n, causal, &mut batched);
            let opts = FastmaxOpts { p, causal, normalize: true };
            let mut single = vec![0.0f32; lanes * n * d];
            for lane in 0..lanes {
                let s = lane * n * d;
                fastmax_attention(&q[s..s + n * d], &k[s..s + n * d], &v[s..s + n * d],
                                  n, d, &opts, &mut single[s..s + n * d]);
            }
            // acceptance: ≤ 1e-3 rel; in practice the paths share code
            // and agree to float exactness
            assert_allclose(&batched, &single, 1e-4, 1e-3);
        }
    }
}

#[test]
fn batched_decode_matches_causal_sweep() {
    for p in [1usize, 2] {
        let (b, h, n, d) = (3usize, 2usize, 48usize, 8usize);
        let lanes = b * h;
        let (q, k, v) = gen(lanes * n * d, 2000 + p as u64);
        // reference: single-head causal forward per lane
        let opts = FastmaxOpts { p, causal: true, normalize: true };
        let mut want = vec![0.0f32; lanes * n * d];
        for lane in 0..lanes {
            let s = lane * n * d;
            fastmax_attention(&q[s..s + n * d], &k[s..s + n * d], &v[s..s + n * d],
                              n, d, &opts, &mut want[s..s + n * d]);
        }
        // incremental batched decode, token by token
        let mut dec = MultiHeadAttention::new(b, h, d, p);
        let mut got = vec![0.0f32; lanes * n * d];
        let mut qt = vec![0.0f32; lanes * d];
        let mut kt = vec![0.0f32; lanes * d];
        let mut vt = vec![0.0f32; lanes * d];
        let mut ot = vec![0.0f32; lanes * d];
        for i in 0..n {
            for lane in 0..lanes {
                let src = lane * n * d + i * d;
                qt[lane * d..(lane + 1) * d].copy_from_slice(&q[src..src + d]);
                kt[lane * d..(lane + 1) * d].copy_from_slice(&k[src..src + d]);
                vt[lane * d..(lane + 1) * d].copy_from_slice(&v[src..src + d]);
            }
            dec.absorb_batch(&kt, &vt);
            dec.readout_batch(&qt, &mut ot);
            for lane in 0..lanes {
                let dst = lane * n * d + i * d;
                got[dst..dst + d].copy_from_slice(&ot[lane * d..(lane + 1) * d]);
            }
        }
        assert_allclose(&got, &want, 1e-4, 1e-3);
    }
}

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 24, n_ctx: 48, d_model: 24, n_layers: 2, n_heads: 3,
        attn: Mechanism::Fastmax2, causal: true, n_classes: 0,
    }
}

#[test]
fn model_batched_decode_matches_per_sequence_loop() {
    let cfg = tiny_cfg();
    let bundle = random_bundle(&cfg, 9);
    let model = NativeModel::from_bundle(cfg, &bundle).unwrap();
    let bsz = 4usize;
    let prompts: Vec<Vec<i32>> =
        (0..bsz).map(|b| vec![b as i32 + 1, 2 * b as i32 + 3, 5]).collect();
    let mut want = Vec::new();
    for prompt in &prompts {
        let mut st = DecodeState::new(&model.cfg).unwrap();
        want.push(model.prefill(prompt, &mut st).unwrap());
    }
    let mut bst = BatchedDecodeState::new(&model.cfg, bsz).unwrap();
    let mut logits = Vec::new();
    for i in 0..3 {
        let toks: Vec<i32> = prompts.iter().map(|p| p[i]).collect();
        logits = model.decode_step_batch(&toks, &mut bst).unwrap().to_vec();
    }
    let vocab = model.cfg.vocab;
    for b in 0..bsz {
        assert_allclose(&logits[b * vocab..(b + 1) * vocab], &want[b], 1e-5, 1e-4);
    }
}

#[test]
fn scheduler_greedy_outputs_are_batch_size_invariant() {
    let cfg = tiny_cfg();
    let bundle = random_bundle(&cfg, 11);
    let run = |batch: usize, n_extra: usize| -> Vec<i32> {
        let model = NativeModel::from_bundle(tiny_cfg(), &bundle).unwrap();
        let scfg = NativeSchedulerConfig { batch, ..Default::default() };
        let mut sched = NativeScheduler::new(model, &scfg).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        sched.submit(Ticket::new(GenRequest::new(0, vec![1, 2, 3], 10, 0.0), tx));
        let mut extra = Vec::new();
        for i in 0..n_extra {
            let (tx2, rx2) = std::sync::mpsc::channel();
            sched.submit(Ticket::new(
                GenRequest::new(50 + i as u64, vec![7, (i as i32) + 1], 10, 0.0),
                tx2));
            extra.push(rx2);
        }
        sched.run_to_completion().unwrap();
        rx.recv().unwrap().tokens
    };
    let solo = run(1, 0);
    assert_eq!(solo.len(), 10);
    assert_eq!(solo, run(4, 3), "B=4 crowded changed greedy output");
    assert_eq!(solo, run(8, 5), "B=8 crowded changed greedy output");
}
