//! Kernel-equivalence suite: the symmetry-aware (and, under
//! `--features simd`, AVX2) moment kernels must match the scalar
//! full-sweep reference within 1e-5 across p ∈ {1, 2} and
//! d ∈ {4, 8, 32, 33} — odd d exercises the 8-wide vector remainder
//! path — plus the cnt == 0 / single-token edge cases and a direct
//! Σ f(q·k)·v oracle. Runs identically with and without the `simd`
//! feature (CI runs both lanes), so a fallback-path regression in
//! either build is caught.

use fast::attention::kernels::{self, tri_len};
use fast::attention::MomentState;
use fast::tensor::ops::poly_f;
use fast::util::prop::{assert_allclose, check, Config};
use fast::util::rng::Rng;

const DIMS: [usize; 4] = [4, 8, 32, 33];

/// Random row at a scale that keeps p = 1 denominators (den = cnt +
/// Σ(1 + q·k̂) terms) comfortably away from zero for every case seed:
/// |q·k| std ≈ 0.3²·√d ≪ cnt. The kernels under test are exercised
/// identically; only the conditioning of the final divide changes.
fn gen_row(rng: &mut Rng, d: usize, scale: f32) -> Vec<f32> {
    rng.normal_vec(d).iter().map(|x| scale * x).collect()
}

/// num/den computed straight from the (k, v) history with f(q·k) —
/// the un-factorized oracle the moments must reproduce exactly (up to
/// float accumulation).
fn direct_readout(q: &[f32], hist: &[(Vec<f32>, Vec<f32>)], p: usize) -> Vec<f32> {
    let d = q.len();
    let mut out = vec![0.0f32; d];
    let mut den = 0.0f32;
    for (k, v) in hist {
        let s: f32 = q.iter().zip(k).map(|(a, b)| a * b).sum();
        let f = poly_f(s, p);
        den += f;
        for (o, vi) in out.iter_mut().zip(v) {
            *o += f * vi;
        }
    }
    for o in out.iter_mut() {
        *o /= den;
    }
    out
}

#[test]
fn property_symmetric_kernels_match_scalar_reference() {
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(8).with_seed(0xD00 + (p * 100 + d) as u64),
                  "kernel equivalence", |rng| {
                let tokens = 9;
                let mut st = MomentState::new(d, p);
                for _ in 0..tokens {
                    let k = gen_row(rng, d, 0.3);
                    let v = rng.normal_vec(d);
                    st.absorb(&k, &v);
                }
                let q = gen_row(rng, d, 0.3);
                let mut sym = vec![0.0f32; d];
                let mut refr = vec![0.0f32; d];
                st.readout(&q, &mut sym);
                kernels::reference::readout(&st, &q, &mut refr);
                assert_allclose(&sym, &refr, 1e-5, 1e-5);
            });
        }
    }
}

#[test]
fn property_blocked_and_fused_match_reference() {
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(6).with_seed(0xB10C + (p * 100 + d) as u64),
                  "blocked/fused equivalence", |rng| {
                let rows = 5usize;
                let mut split = MomentState::new(d, p);
                let mut fused = MomentState::new(d, p);
                for _ in 0..7 {
                    let k = gen_row(rng, d, 0.3);
                    let v = rng.normal_vec(d);
                    let q = gen_row(rng, d, 0.3);
                    let mut o_split = vec![0.0f32; d];
                    let mut o_fused = vec![0.0f32; d];
                    split.absorb(&k, &v);
                    split.readout(&q, &mut o_split);
                    fused.absorb_readout(&k, &v, &q, &mut o_fused);
                    assert_allclose(&o_fused, &o_split, 1e-5, 1e-5);
                }
                // states themselves must agree tile-for-tile
                assert_allclose(&fused.x3, &split.x3, 1e-5, 1e-4);
                // blocked rows vs per-row reference sweep
                let q = gen_row(rng, rows * d, 0.3);
                let mut blocked = vec![0.0f32; rows * d];
                split.readout_rows(&q, &mut blocked);
                for i in 0..rows {
                    let mut one = vec![0.0f32; d];
                    kernels::reference::readout(&split, &q[i * d..(i + 1) * d],
                                                &mut one);
                    assert_allclose(&blocked[i * d..(i + 1) * d], &one, 1e-5, 1e-5);
                }
            });
        }
    }
}

#[test]
fn moments_match_direct_poly_oracle() {
    for p in [1usize, 2] {
        for d in DIMS {
            let mut rng = Rng::new(0x0AC1E + (p * 100 + d) as u64);
            let mut st = MomentState::new(d, p);
            let mut hist = Vec::new();
            for _ in 0..6 {
                let k = gen_row(&mut rng, d, 0.3);
                let v = rng.normal_vec(d);
                st.absorb(&k, &v);
                hist.push((k, v));
            }
            let q = gen_row(&mut rng, d, 0.3);
            let mut got = vec![0.0f32; d];
            st.readout(&q, &mut got);
            let want = direct_readout(&q, &hist, p);
            // factorization is exact math; tolerance covers f32
            // accumulation-order differences at d = 32/33
            assert_allclose(&got, &want, 1e-3, 1e-3);
        }
    }
}

#[test]
fn empty_state_all_readout_paths_return_zeros() {
    for p in [1usize, 2] {
        for d in DIMS {
            let st = MomentState::new(d, p);
            let mut rng = Rng::new(42 + d as u64);
            let q = rng.normal_vec(d);
            let mut out = vec![f32::NAN; d];
            st.readout(&q, &mut out);
            assert!(out.iter().all(|&x| x == 0.0), "readout p={p} d={d}");
            let mut refr = vec![f32::NAN; d];
            kernels::reference::readout(&st, &q, &mut refr);
            assert!(refr.iter().all(|&x| x == 0.0), "reference p={p} d={d}");
            let rows = 3;
            let qr = rng.normal_vec(rows * d);
            let mut block = vec![f32::NAN; rows * d];
            st.readout_rows(&qr, &mut block);
            assert!(block.iter().all(|&x| x == 0.0), "rows p={p} d={d}");
        }
    }
}

#[test]
fn single_token_readout_is_v() {
    // one absorbed token: out = f(q·k)·v / f(q·k) = v for any p with
    // a non-cancelled denominator
    for p in [1usize, 2] {
        for d in DIMS {
            let mut st = MomentState::new(d, p);
            let k: Vec<f32> = (0..d).map(|i| 0.1 + 0.01 * i as f32).collect();
            let v: Vec<f32> = (0..d).map(|i| i as f32 - 2.0).collect();
            st.absorb(&k, &v);
            let q = vec![0.2f32; d]; // q·k > 0 ⇒ den > 0 for both p
            let mut out = vec![0.0f32; d];
            st.readout(&q, &mut out);
            assert_allclose(&out, &v, 1e-4, 1e-4);
            let mut fused = MomentState::new(d, p);
            let mut o2 = vec![0.0f32; d];
            fused.absorb_readout(&k, &v, &q, &mut o2);
            assert_allclose(&o2, &v, 1e-4, 1e-4);
        }
    }
}

#[test]
fn packed_flat_roundtrip_and_merge_across_dims() {
    for d in DIMS {
        let mut rng = Rng::new(d as u64);
        let mut a = MomentState::new(d, 2);
        let mut b = MomentState::new(d, 2);
        let mut whole = MomentState::new(d, 2);
        for i in 0..8 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            whole.absorb(&k, &v);
            if i < 4 { a.absorb(&k, &v) } else { b.absorb(&k, &v) }
        }
        a.merge(&b);
        let q = rng.normal_vec(d);
        let (mut o1, mut o2) = (vec![0.0f32; d], vec![0.0f32; d]);
        whole.readout(&q, &mut o1);
        a.readout(&q, &mut o2);
        assert_allclose(&o2, &o1, 1e-4, 1e-3);
        // packed wire format: length is 1 + D + D² + D + tri·D + tri
        let flat = whole.to_flat();
        assert_eq!(flat.len(), 1 + d + d * d + d + tri_len(d) * d + tri_len(d));
        let back = MomentState::from_flat(d, 2, &flat);
        assert_eq!(back, whole);
    }
}
