//! Kernel-equivalence suite: the symmetry-aware (and, under
//! `--features simd`, AVX2) moment kernels must match the scalar
//! full-sweep reference within 1e-5 across p ∈ {1, 2} and
//! d ∈ {4, 8, 32, 33} — odd d exercises the 8-wide vector remainder
//! path — plus the cnt == 0 / single-token edge cases and a direct
//! Σ f(q·k)·v oracle. Runs identically with and without the `simd`
//! feature (CI runs both lanes), so a fallback-path regression in
//! either build is caught.

use fast::attention::kernels::{self, tri_len};
use fast::attention::{MomentState, StateDtype};
use fast::tensor::ops::poly_f;
use fast::util::prop::{assert_allclose, check, Config};
use fast::util::rng::Rng;

const DIMS: [usize; 4] = [4, 8, 32, 33];

/// Pinned quantized-vs-f32 readout error bounds (used as both atol and
/// rtol). Empirical worst cases over this suite's exact (seed, p, d)
/// grid — measured against a Python mirror of the banks and sweeps —
/// are ≤ 5.6e-4 (f16) and ≤ 8.6e-3 (int8); the pins carry ~4×
/// headroom for kernel-dispatch reassociation (scalar vs FMA lanes).
/// Errors here are *absolute*-dominated: readout divides by den, so
/// near-cancelled outputs make relative error unbounded by design.
const F16_TOL: f32 = 2.5e-3;
const INT8_TOL: f32 = 4e-2;

/// Random row at a scale that keeps p = 1 denominators (den = cnt +
/// Σ(1 + q·k̂) terms) comfortably away from zero for every case seed:
/// |q·k| std ≈ 0.3²·√d ≪ cnt. The kernels under test are exercised
/// identically; only the conditioning of the final divide changes.
fn gen_row(rng: &mut Rng, d: usize, scale: f32) -> Vec<f32> {
    rng.normal_vec(d).iter().map(|x| scale * x).collect()
}

/// num/den computed straight from the (k, v) history with f(q·k) —
/// the un-factorized oracle the moments must reproduce exactly (up to
/// float accumulation).
fn direct_readout(q: &[f32], hist: &[(Vec<f32>, Vec<f32>)], p: usize) -> Vec<f32> {
    let d = q.len();
    let mut out = vec![0.0f32; d];
    let mut den = 0.0f32;
    for (k, v) in hist {
        let s: f32 = q.iter().zip(k).map(|(a, b)| a * b).sum();
        let f = poly_f(s, p);
        den += f;
        for (o, vi) in out.iter_mut().zip(v) {
            *o += f * vi;
        }
    }
    for o in out.iter_mut() {
        *o /= den;
    }
    out
}

#[test]
fn property_symmetric_kernels_match_scalar_reference() {
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(8).with_seed(0xD00 + (p * 100 + d) as u64),
                  "kernel equivalence", |rng| {
                let tokens = 9;
                let mut st = MomentState::new(d, p);
                for _ in 0..tokens {
                    let k = gen_row(rng, d, 0.3);
                    let v = rng.normal_vec(d);
                    st.absorb(&k, &v);
                }
                let q = gen_row(rng, d, 0.3);
                let mut sym = vec![0.0f32; d];
                let mut refr = vec![0.0f32; d];
                st.readout(&q, &mut sym);
                kernels::reference::readout(&st, &q, &mut refr);
                assert_allclose(&sym, &refr, 1e-5, 1e-5);
            });
        }
    }
}

#[test]
fn property_blocked_and_fused_match_reference() {
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(6).with_seed(0xB10C + (p * 100 + d) as u64),
                  "blocked/fused equivalence", |rng| {
                let rows = 5usize;
                let mut split = MomentState::new(d, p);
                let mut fused = MomentState::new(d, p);
                for _ in 0..7 {
                    let k = gen_row(rng, d, 0.3);
                    let v = rng.normal_vec(d);
                    let q = gen_row(rng, d, 0.3);
                    let mut o_split = vec![0.0f32; d];
                    let mut o_fused = vec![0.0f32; d];
                    split.absorb(&k, &v);
                    split.readout(&q, &mut o_split);
                    fused.absorb_readout(&k, &v, &q, &mut o_fused);
                    assert_allclose(&o_fused, &o_split, 1e-5, 1e-5);
                }
                // states themselves must agree tile-for-tile
                assert_allclose(&fused.x3_dense(), &split.x3_dense(), 1e-5, 1e-4);
                // blocked rows vs per-row reference sweep
                let q = gen_row(rng, rows * d, 0.3);
                let mut blocked = vec![0.0f32; rows * d];
                split.readout_rows(&q, &mut blocked);
                for i in 0..rows {
                    let mut one = vec![0.0f32; d];
                    kernels::reference::readout(&split, &q[i * d..(i + 1) * d],
                                                &mut one);
                    assert_allclose(&blocked[i * d..(i + 1) * d], &one, 1e-5, 1e-5);
                }
            });
        }
    }
}

#[test]
fn moments_match_direct_poly_oracle() {
    for p in [1usize, 2] {
        for d in DIMS {
            let mut rng = Rng::new(0x0AC1E + (p * 100 + d) as u64);
            let mut st = MomentState::new(d, p);
            let mut hist = Vec::new();
            for _ in 0..6 {
                let k = gen_row(&mut rng, d, 0.3);
                let v = rng.normal_vec(d);
                st.absorb(&k, &v);
                hist.push((k, v));
            }
            let q = gen_row(&mut rng, d, 0.3);
            let mut got = vec![0.0f32; d];
            st.readout(&q, &mut got);
            let want = direct_readout(&q, &hist, p);
            // factorization is exact math; tolerance covers f32
            // accumulation-order differences at d = 32/33
            assert_allclose(&got, &want, 1e-3, 1e-3);
        }
    }
}

#[test]
fn empty_state_all_readout_paths_return_zeros() {
    for p in [1usize, 2] {
        for d in DIMS {
            let st = MomentState::new(d, p);
            let mut rng = Rng::new(42 + d as u64);
            let q = rng.normal_vec(d);
            let mut out = vec![f32::NAN; d];
            st.readout(&q, &mut out);
            assert!(out.iter().all(|&x| x == 0.0), "readout p={p} d={d}");
            let mut refr = vec![f32::NAN; d];
            kernels::reference::readout(&st, &q, &mut refr);
            assert!(refr.iter().all(|&x| x == 0.0), "reference p={p} d={d}");
            let rows = 3;
            let qr = rng.normal_vec(rows * d);
            let mut block = vec![f32::NAN; rows * d];
            st.readout_rows(&qr, &mut block);
            assert!(block.iter().all(|&x| x == 0.0), "rows p={p} d={d}");
        }
    }
}

#[test]
fn single_token_readout_is_v() {
    // one absorbed token: out = f(q·k)·v / f(q·k) = v for any p with
    // a non-cancelled denominator
    for p in [1usize, 2] {
        for d in DIMS {
            let mut st = MomentState::new(d, p);
            let k: Vec<f32> = (0..d).map(|i| 0.1 + 0.01 * i as f32).collect();
            let v: Vec<f32> = (0..d).map(|i| i as f32 - 2.0).collect();
            st.absorb(&k, &v);
            let q = vec![0.2f32; d]; // q·k > 0 ⇒ den > 0 for both p
            let mut out = vec![0.0f32; d];
            st.readout(&q, &mut out);
            assert_allclose(&out, &v, 1e-4, 1e-4);
            let mut fused = MomentState::new(d, p);
            let mut o2 = vec![0.0f32; d];
            fused.absorb_readout(&k, &v, &q, &mut o2);
            assert_allclose(&o2, &v, 1e-4, 1e-4);
        }
    }
}

#[test]
fn property_quantized_readout_error_pinned() {
    // split path: absorb the same token stream into f32/f16/int8 banks,
    // read the same query — the quantized banks must stay within the
    // pinned bounds of the f32 reference
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(4).with_seed(0x9A00 + (p * 100 + d) as u64),
                  "quantized split accuracy", |rng| {
                let tokens = 9;
                let mut f32_st = MomentState::new(d, p);
                let mut f16_st = MomentState::new_with_dtype(d, p, StateDtype::F16);
                let mut i8_st = MomentState::new_with_dtype(d, p, StateDtype::Int8);
                for _ in 0..tokens {
                    let k = gen_row(rng, d, 0.3);
                    let v = rng.normal_vec(d);
                    f32_st.absorb(&k, &v);
                    f16_st.absorb(&k, &v);
                    i8_st.absorb(&k, &v);
                }
                let q = gen_row(rng, d, 0.3);
                let mut want = vec![0.0f32; d];
                let mut got = vec![0.0f32; d];
                f32_st.readout(&q, &mut want);
                f16_st.readout(&q, &mut got);
                assert_allclose(&got, &want, F16_TOL, F16_TOL);
                i8_st.readout(&q, &mut got);
                assert_allclose(&got, &want, INT8_TOL, INT8_TOL);
            });
        }
    }
}

#[test]
fn property_quantized_fused_decode_error_pinned() {
    // fused path: per-token absorb_readout — the widen-update-requantize
    // single pass — tracks the f32 fused step within the same bounds at
    // every token, so quantization error does not compound across a
    // decode stream
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(4).with_seed(0xF05D + (p * 100 + d) as u64),
                  "quantized fused accuracy", |rng| {
                let mut f32_st = MomentState::new(d, p);
                let mut f16_st = MomentState::new_with_dtype(d, p, StateDtype::F16);
                let mut i8_st = MomentState::new_with_dtype(d, p, StateDtype::Int8);
                for _ in 0..9 {
                    let k = gen_row(rng, d, 0.3);
                    let v = rng.normal_vec(d);
                    let q = gen_row(rng, d, 0.3);
                    let mut want = vec![0.0f32; d];
                    let mut got16 = vec![0.0f32; d];
                    let mut got8 = vec![0.0f32; d];
                    f32_st.absorb_readout(&k, &v, &q, &mut want);
                    f16_st.absorb_readout(&k, &v, &q, &mut got16);
                    i8_st.absorb_readout(&k, &v, &q, &mut got8);
                    assert_allclose(&got16, &want, F16_TOL, F16_TOL);
                    assert_allclose(&got8, &want, INT8_TOL, INT8_TOL);
                }
            });
        }
    }
}

#[test]
fn quantized_empty_state_returns_exact_zeros() {
    // cnt == 0 edge: the den guard must fire identically for quantized
    // banks on every readout path — exact zeros, no NaN, no dequant noise
    for dtype in [StateDtype::F16, StateDtype::Int8] {
        for p in [1usize, 2] {
            for d in DIMS {
                let st = MomentState::new_with_dtype(d, p, dtype);
                let mut rng = Rng::new(90 + d as u64);
                let q = rng.normal_vec(d);
                let mut out = vec![f32::NAN; d];
                st.readout(&q, &mut out);
                assert!(out.iter().all(|&x| x == 0.0),
                        "readout {} p={p} d={d}", dtype.name());
                let rows = 3;
                let qr = rng.normal_vec(rows * d);
                let mut block = vec![f32::NAN; rows * d];
                st.readout_rows(&qr, &mut block);
                assert!(block.iter().all(|&x| x == 0.0),
                        "rows {} p={p} d={d}", dtype.name());
            }
        }
    }
}

#[test]
fn quantized_single_token_readout_is_v() {
    // single-token edge: out = f(q·k)·v / f(q·k) = v up to the storage
    // quantization of the one absorbed token's moments
    for p in [1usize, 2] {
        for d in DIMS {
            let mut rng = Rng::new(0x51 + (p * 100 + d) as u64);
            let k = gen_row(&mut rng, d, 0.3);
            let v = rng.normal_vec(d);
            let q = gen_row(&mut rng, d, 0.3);
            for (dtype, tol) in [(StateDtype::F16, F16_TOL),
                                 (StateDtype::Int8, INT8_TOL)] {
                let mut st = MomentState::new_with_dtype(d, p, dtype);
                st.absorb(&k, &v);
                let mut out = vec![0.0f32; d];
                st.readout(&q, &mut out);
                assert_allclose(&out, &v, tol, tol);
                let mut fused = MomentState::new_with_dtype(d, p, dtype);
                let mut o2 = vec![0.0f32; d];
                fused.absorb_readout(&k, &v, &q, &mut o2);
                assert_allclose(&o2, &v, tol, tol);
            }
        }
    }
}

#[test]
fn property_quantized_merge_then_readout_stays_bounded() {
    // sharded-prefill shape: two quantized halves merged (widen → add →
    // one requantization) must read out within the pinned bounds of the
    // all-f32 sequential state
    for p in [1usize, 2] {
        for d in DIMS {
            check(Config::cases(4).with_seed(0x3E6E + (p * 100 + d) as u64),
                  "quantized merge accuracy", |rng| {
                let tokens: Vec<(Vec<f32>, Vec<f32>)> = (0..12)
                    .map(|_| (gen_row(rng, d, 0.3), rng.normal_vec(d)))
                    .collect();
                let q = gen_row(rng, d, 0.3);
                let mut whole = MomentState::new(d, p);
                for (k, v) in &tokens {
                    whole.absorb(k, v);
                }
                let mut want = vec![0.0f32; d];
                whole.readout(&q, &mut want);
                for (dtype, tol) in [(StateDtype::F16, F16_TOL),
                                     (StateDtype::Int8, INT8_TOL)] {
                    let mut left = MomentState::new_with_dtype(d, p, dtype);
                    let mut right = MomentState::new_with_dtype(d, p, dtype);
                    for (k, v) in &tokens[..6] {
                        left.absorb(k, v);
                    }
                    for (k, v) in &tokens[6..] {
                        right.absorb(k, v);
                    }
                    left.merge(&right);
                    assert_eq!(left.dtype(), dtype);
                    let mut got = vec![0.0f32; d];
                    left.readout(&q, &mut got);
                    assert_allclose(&got, &want, tol, tol);
                }
            });
        }
    }
}

#[test]
fn packed_flat_roundtrip_and_merge_across_dims() {
    for d in DIMS {
        let mut rng = Rng::new(d as u64);
        let mut a = MomentState::new(d, 2);
        let mut b = MomentState::new(d, 2);
        let mut whole = MomentState::new(d, 2);
        for i in 0..8 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            whole.absorb(&k, &v);
            if i < 4 { a.absorb(&k, &v) } else { b.absorb(&k, &v) }
        }
        a.merge(&b);
        let q = rng.normal_vec(d);
        let (mut o1, mut o2) = (vec![0.0f32; d], vec![0.0f32; d]);
        whole.readout(&q, &mut o1);
        a.readout(&q, &mut o2);
        assert_allclose(&o2, &o1, 1e-4, 1e-3);
        // packed wire format: length is 1 + D + D² + D + tri·D + tri
        let flat = whole.to_flat();
        assert_eq!(flat.len(), 1 + d + d * d + d + tri_len(d) * d + tri_len(d));
        let back = MomentState::from_flat(d, 2, &flat);
        assert_eq!(back, whole);
    }
}
